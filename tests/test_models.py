"""Model-zoo correctness: per-arch smoke + decode/prefill consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_smoke_config
from repro.models.model import Model

KEY = jax.random.PRNGKey(1)


def make_batch(cfg, B, S, key=KEY):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
             "loss_mask": jnp.ones((B, S), jnp.int32)}
    if cfg.family == "vlm":
        P = cfg.num_patches
        batch = {"tokens": jax.random.randint(key, (B, S - P), 0,
                                              cfg.vocab_size),
                 "patches": jax.random.normal(key, (B, P, cfg.d_model)),
                 "loss_mask": jnp.ones((B, S - P), jnp.int32)}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model))
    return batch


def dropless(cfg):
    if cfg.moe is None:
        return cfg
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=100.0))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_shapes_and_finite(arch):
    """Assignment requirement: reduced same-family variant, one
    forward/train step on CPU, output shapes + no NaNs."""
    cfg = get_smoke_config(arch)
    model = Model(cfg)
    params = model.init(KEY)
    B, S = 2, 32
    batch = make_batch(cfg, B, S)
    logits, _, aux = model.logits_full(params, batch)
    S_out = S - (cfg.num_patches if cfg.family == "vlm" else 0)
    exp_S = S_out + (cfg.num_patches if cfg.family == "vlm" else 0)
    assert logits.shape[0] == B and logits.shape[1] == exp_S
    assert logits.shape[2] >= cfg.vocab_size
    assert bool(jnp.isfinite(logits).all()), arch
    loss, metrics = model.loss(params, batch)
    assert bool(jnp.isfinite(loss)), (arch, loss)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_train_step(arch):
    from repro.training.optimizer import OptimizerConfig, init_adamw
    from repro.training.train_loop import make_train_step
    cfg = get_smoke_config(arch)
    model = Model(cfg)
    params = model.init(KEY)
    opt = init_adamw(params)
    batch = make_batch(cfg, 2, 32)
    step = jax.jit(make_train_step(model, OptimizerConfig(total_steps=10)))
    params2, opt2, metrics = step(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually changed
    diff = sum(float(jnp.abs(a - b).sum()) for a, b in zip(
        jax.tree_util.tree_leaves(params),
        jax.tree_util.tree_leaves(params2)))
    assert diff > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_matches_full_forward(arch):
    """prefill(S-1) + decode(1) == full forward logits at the last pos.

    Uses dropless capacity so MoE token-drop nondeterminism cannot differ
    between the two paths."""
    cfg = dropless(get_smoke_config(arch))
    model = Model(cfg)
    params = model.init(KEY)
    B, S = 2, 17
    batch = make_batch(cfg, B, S)
    full_logits, _, _ = model.logits_full(params, batch)
    S_tok = batch["tokens"].shape[1]   # excludes VLM patch prefix
    b2 = dict(batch)
    b2["tokens"] = batch["tokens"][:, : S_tok - 1]
    if "loss_mask" in b2:
        b2["loss_mask"] = batch["loss_mask"][:, : S_tok - 1]
    last, cache = model.prefill(params, b2, max_seq=32)
    dec_logits, _ = model.decode_step(params, cache,
                                      batch["tokens"][:, S_tok - 1])
    ref = full_logits[:, -1]
    scale = float(jnp.max(jnp.abs(ref))) + 1e-9
    rel = float(jnp.max(jnp.abs(dec_logits - ref))) / scale
    assert rel < 5e-3, (arch, rel)


def test_sliding_window_decode_matches_windowed_full():
    """Ring-buffer decode with window W must equal full attention
    restricted to the last W tokens."""
    cfg = get_smoke_config("internlm2-20b")
    cfg_w = dataclasses.replace(cfg, sliding_window=8)
    model = Model(cfg_w)
    params = model.init(KEY)
    B, S = 1, 24
    batch = make_batch(cfg_w, B, S)
    full_logits, _, _ = model.logits_full(params, batch)  # masked to window
    b2 = {"tokens": batch["tokens"][:, : S - 1],
          "loss_mask": batch["loss_mask"][:, : S - 1]}
    last, cache = model.prefill(params, b2, max_seq=S)
    dec, _ = model.decode_step(params, cache, batch["tokens"][:, S - 1])
    ref = full_logits[:, -1]
    rel = float(jnp.max(jnp.abs(dec - ref))) / (
        float(jnp.max(jnp.abs(ref))) + 1e-9)
    assert rel < 5e-3, rel


def test_moe_runtime_changes_routing_without_retrace():
    """Masking an expert is a data change: same compiled decode fn."""
    cfg = dropless(get_smoke_config("qwen2-moe-a2.7b"))
    model = Model(cfg)
    params = model.init(KEY)
    batch = make_batch(cfg, 2, 8)
    _, cache = model.prefill(params, batch, max_seq=16)
    tok = jnp.array([1, 2], jnp.int32)
    fn = jax.jit(model.decode_step)
    rt1 = model.default_runtime()
    l1, _ = fn(params, cache, tok, rt1)
    n = fn._cache_size()
    rt2 = rt1._replace(expert_mask=rt1.expert_mask.at[0].set(False))
    l2, _ = fn(params, cache, tok, rt2)
    assert fn._cache_size() == n          # no recompile (§3.4)
    assert float(jnp.max(jnp.abs(l1 - l2))) > 0  # routing actually changed


def test_redundant_replica_equivalence():
    """Replicas are exact copies: dropping a replica of a duplicated
    expert must not change the model output (lossless recovery)."""
    from repro.configs.base import MoEConfig
    from repro.core.expert_map import ExpertMap
    cfg = get_smoke_config("qwen2-moe-a2.7b")
    cfg = dataclasses.replace(
        cfg, moe=MoEConfig(num_experts=4, top_k=2, expert_d_ff=128,
                           num_redundant_experts=4, capacity_factor=100.0))
    model = Model(cfg)
    params = model.init(KEY)
    batch = make_batch(cfg, 2, 16)
    emap = ExpertMap(cfg.moe, ep_size=2)
    logits_healthy, _, _ = model.logits_full(params, batch, emap.runtime())
    emap.fail_rank(1)             # rank1 = replicas only -> still covered
    assert emap.fully_lost() == []
    logits_failed, _, _ = model.logits_full(params, batch, emap.runtime())
    np.testing.assert_allclose(np.asarray(logits_healthy),
                               np.asarray(logits_failed), rtol=1e-4,
                               atol=1e-4)
