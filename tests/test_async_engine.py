"""Async pipelined engine (host/device overlap + async readback).

The overlap pipeline plans step N+1 against the *predicted* post-N
state while step N runs on device, samples on-device with the same
position-seeded uniforms the host sampler uses, and commits one step
late off a ring of in-flight D2H copies.  The contract under test:

* the emitted token stream is bit-identical to lockstep — any
  temperature, spec on or off, every attention architecture;
* a fault while a step is in flight replays to lockstep's exact
  stream (the pending step's readback predates the fault, so its
  outcome commits; everything uncommitted rolls back via §3.3);
* a mispredicted plan (speculation accept-count miss) reconciles
  through the lockstep commit path and replans — never a wrong token;
* the vectorized position-seeded sampler stays bit-equal to the
  per-row ``np.random.default_rng`` reference it replaced.
"""
import dataclasses

import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.fault_codes import ErrorType, Severity
from repro.serving.engine import EngineConfig, InferenceEngine
from repro.serving.sampling import SamplingParams, seeded_uniforms

PAT_A = [5, 9, 2, 7]
PAT_B = [3, 1]


def _prompts():
    return [PAT_A * 5, PAT_B * 8]


def _engine(tmp_path, sub, *, overlap=False, spec_window=0,
            temperature=0.0, num_dp=1, **over):
    cfg = get_smoke_config(over.pop("arch", "qwen2-moe-a2.7b"))
    cfg_fn = over.pop("cfg_fn", None)
    if cfg_fn:
        cfg = cfg_fn(cfg)
    ec = EngineConfig(mode="collocated", num_dp=num_dp, max_batch=2,
                      max_seq=96, block_size=8, num_blocks=64,
                      workdir=str(tmp_path / sub), overlap=overlap,
                      spec_window=spec_window,
                      sampling=SamplingParams(temperature=temperature,
                                              top_p=0.9, seed=3), **over)
    return cfg, InferenceEngine(cfg, ec)


def _serve(eng, prompts, max_new=24):
    reqs = [eng.submit(list(p), max_new) for p in prompts]
    eng.run(max_steps=400)
    assert all(r.state.value == "finished" for r in reqs), \
        [r.state for r in reqs]
    return [list(r.output_tokens) for r in reqs]


# -- config validation ------------------------------------------------------


def test_overlap_requires_row_undo_and_chunked_admission(tmp_path):
    with pytest.raises(ValueError, match="pool_undo"):
        EngineConfig(workdir=str(tmp_path), overlap=True,
                     pool_undo="snapshot")
    with pytest.raises(ValueError, match="admission"):
        EngineConfig(workdir=str(tmp_path), overlap=True,
                     admission="serial")


# -- token exactness vs lockstep --------------------------------------------


def _windowed(cfg):
    return dataclasses.replace(cfg, sliding_window=6)


ARCHS = [
    ("qwen2-moe-a2.7b", None),       # GQA + MoE + shared experts
    ("deepseek-v3", None),           # MLA + MoE + first-k-dense
    ("qwen2-moe-a2.7b", _windowed),  # GQA + sliding window
]


@pytest.mark.parametrize("arch,cfg_fn", ARCHS,
                         ids=["gqa_moe", "mla_moe", "windowed"])
def test_overlap_token_exact_vs_lockstep(tmp_path, arch, cfg_fn):
    _, base = _engine(tmp_path, "base", arch=arch, cfg_fn=cfg_fn)
    want = _serve(base, _prompts())
    _, eng = _engine(tmp_path, "ov", arch=arch, cfg_fn=cfg_fn,
                     overlap=True)
    got = _serve(eng, _prompts())
    assert got == want
    st = eng.overlap_stats()
    assert st["planned_ahead"] > 0        # the pipeline actually piped
    assert st["replans"] == 0             # greedy device argmax is exact
    assert eng.host_gap_fraction() < 1.0


@pytest.mark.parametrize("temperature", [0.3, 0.8])
def test_overlap_token_exact_any_temperature(tmp_path, temperature):
    """The device epilogue samples with the same position-seeded
    uniforms as the host sampler; a last-ULP divergence may cost a
    replan but never a different token."""
    _, base = _engine(tmp_path, "base", temperature=temperature)
    want = _serve(base, _prompts())
    _, eng = _engine(tmp_path, "ov", temperature=temperature,
                     overlap=True)
    got = _serve(eng, _prompts())
    assert got == want
    assert eng.overlap_stats()["planned_ahead"] > 0


@pytest.mark.parametrize("temperature", [0.0, 0.8])
def test_overlap_with_spec_decode_reconciles(tmp_path, temperature):
    """Speculation makes per-step emit counts unpredictable at plan
    time: the stacked plan-ahead step gets unwound (pool rows restored
    newest-first) and the true outcome committed via the lockstep
    path — the mispredicted-plan reconcile case, still token-exact."""
    _, base = _engine(tmp_path, "base", spec_window=6,
                      temperature=temperature)
    want = _serve(base, _prompts())
    _, eng = _engine(tmp_path, "ov", spec_window=6,
                     temperature=temperature, overlap=True)
    got = _serve(eng, _prompts())
    assert got == want
    st = eng.overlap_stats()
    assert st["planned_ahead"] > 0
    assert st["replans"] >= 1             # accept-count misses happened
    assert eng.prefill_stats()["spec_windows"] > 0


# -- fault while a step is in flight ----------------------------------------


def test_fault_mid_overlap_replays_to_lockstep_stream(tmp_path):
    """Device fault with a step in flight: the pending step's outcome
    commits (its readback predates the fault), §3.3 rolls back the
    rest, and migration + position-seeded replay reproduce lockstep's
    exact stream — recovery included."""
    def serve(sub, overlap):
        _, eng = _engine(tmp_path, sub, num_dp=2, temperature=0.7,
                         overlap=overlap)
        eng.injector.schedule(3, 1, severity=Severity.L6,
                              error_type=ErrorType.HBM_ECC,
                              component="attn", mid_step=True)
        out = _serve(eng, _prompts())
        assert eng.reports, "fault never recovered"
        return out, eng

    want, _ = serve("lock", overlap=False)
    got, eng = serve("ov", overlap=True)
    assert got == want
    assert eng.overlap_stats()["planned_ahead"] > 0


# -- vectorized position-seeded sampler regression --------------------------


def test_seeded_uniforms_match_reference_generator():
    """The batched PCG64/SeedSequence replication must stay bit-equal
    to the per-row ``default_rng`` construction it replaced — this is
    what makes every token a pure function of (seed, prefix,
    position) across executors, instances, and replays."""
    rng = np.random.default_rng(0)
    for seed in (0, 1, 3, 17, 2 ** 31 - 1):
        steps = np.concatenate([
            np.arange(0, 40, dtype=np.int64),
            rng.integers(0, 100_000, 64).astype(np.int64),
        ])
        got = seeded_uniforms(seed, steps)
        base = seed * 1_000_003
        want = np.asarray([
            np.random.default_rng(base + int(s)).random()
            for s in steps])
        np.testing.assert_array_equal(got, want)
