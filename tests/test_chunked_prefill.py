"""Continuous-batching admission pipeline: batched chunked prefill,
shared-prefix block cache with COW, row-level pool undo, sliding-window
block release, and budgeted requeue — the PR-4 invariants.

Token parity is the backbone: chunked prefill (prompt tokens as virtual
decode slots over the paged pools), whole-prompt serial prefill, and
prefix-cache-accelerated prefill must all continue the identical
position-seeded token stream.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.block_log import BlockLog, BlockManager
from repro.models.model import Model
from repro.serving.engine import EngineConfig, InferenceEngine
from repro.serving.request import Request
from repro.serving.sampling import SamplingParams
from repro.serving.scheduler import LocalScheduler


def _engine(tmp_path, name="internlm2-20b", sub="e", **over):
    cfg = get_smoke_config(name)
    if name == "qwen2-moe-a2.7b":
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, num_experts=4, num_redundant_experts=2, top_k=2,
            capacity_factor=8.0, min_capacity=64))
    if over.pop("windowed", False):
        cfg = dataclasses.replace(cfg, sliding_window=16)
    ec = EngineConfig(mode="collocated", num_dp=1, max_batch=4,
                      max_seq=over.pop("max_seq", 64), block_size=8,
                      num_blocks=64, workdir=str(tmp_path / sub),
                      sampling=SamplingParams(temperature=0.8, top_p=0.9,
                                              seed=3),
                      **over)
    return cfg, InferenceEngine(cfg, ec)


def _serve(eng, cfg, prompts, max_new=8):
    reqs = [eng.submit(list(p), max_new) for p in prompts]
    eng.run(max_steps=400)
    assert all(r.state.value == "finished" for r in reqs), \
        [r.state for r in reqs]
    return [list(r.output_tokens) for r in reqs]


def _mixed_prompts(cfg, seed=1):
    rng = np.random.default_rng(seed)
    sysp = list(rng.integers(0, cfg.vocab_size, 20))
    return [list(rng.integers(0, cfg.vocab_size, 45)),   # long
            sysp + list(rng.integers(0, cfg.vocab_size, 5)),
            sysp + list(rng.integers(0, cfg.vocab_size, 9)),
            list(rng.integers(0, cfg.vocab_size, 3))]    # short


def test_chunked_equals_serial_token_parity(tmp_path):
    """Acceptance: the chunked token-budget admission pipeline produces
    exactly the tokens of the one-whole-prefill-per-step baseline, with
    and without the shared-prefix cache, on a mixed long/short workload
    (long prompts span several chunks and interleave with decodes)."""
    cfg, chunked = _engine(tmp_path, sub="c")
    _, nocache = _engine(tmp_path, sub="n", prefix_cache=False)
    _, serial = _engine(tmp_path, sub="s", admission="serial")
    prompts = _mixed_prompts(cfg)
    a = _serve(chunked, cfg, prompts)
    b = _serve(nocache, cfg, prompts)
    c = _serve(serial, cfg, prompts)
    assert a == b == c
    stats = chunked.prefill_stats()
    assert stats["prefill_chunks"] >= 2         # the 45-tok prompt chunked
    assert stats["prefill_tokens_cached"] > 0   # shared prefix hit


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["qwen2-moe-a2.7b", "minicpm3-4b"])
def test_chunked_parity_across_archs(tmp_path, arch):
    """Same three-way parity on MoE (GQA) and MLA (fused latent pool)."""
    cfg, chunked = _engine(tmp_path, arch, sub="c")
    _, serial = _engine(tmp_path, arch, sub="s", admission="serial")
    prompts = _mixed_prompts(cfg)
    assert _serve(chunked, cfg, prompts) == _serve(serial, cfg, prompts)


def test_shared_prefix_cache_hits_and_cow_divergence(tmp_path):
    """A finished request's prompt blocks stay content-addressable: a
    later request sharing 2.5 blocks of prefix reuses the 2 full blocks
    by digest and COW-copies the half-shared divergence block — 20 of
    its prompt tokens skip prefill compute — while producing exactly the
    tokens of a cache-cold engine."""
    cfg, eng = _engine(tmp_path, sub="c")
    _, cold = _engine(tmp_path, sub="f", prefix_cache=False)
    rng = np.random.default_rng(7)
    sysp = list(rng.integers(0, cfg.vocab_size, 20))     # 2.5 blocks
    pa = sysp + list(rng.integers(0, cfg.vocab_size, 6))
    pb = sysp + list(rng.integers(0, cfg.vocab_size, 7))
    # serve A to completion first so its blocks are parked in the cache
    out_a = _serve(eng, cfg, [pa])
    assert eng.prefill_stats()["prefill_tokens_cached"] == 0
    assert eng.dp_executors[0].block_manager.num_cached > 0
    out_b = _serve(eng, cfg, [pb])
    stats = eng.prefill_stats()
    # blocks 0,1 full-match (16) + 4-token COW at the divergence block
    assert stats["prefill_tokens_cached"] == 20
    assert stats["prefix_cache_hits"] == 2      # the two full blocks
    assert _serve(cold, cfg, [pa]) == out_a
    assert _serve(cold, cfg, [pb]) == out_b
    # drained: every shared block was released exactly once
    ex = eng.dp_executors[0]
    assert ex.block_manager.num_allocated == 0
    ex.scheduler.check_consistent()


def test_fault_during_chunked_prefill_replays_exactly(tmp_path):
    """A mid-step device loss while a long prompt is mid-chunk rolls the
    step back (row-level pool undo) and replays the request elsewhere —
    the token stream must equal the no-fault reference."""
    from repro.core.fault_codes import ErrorType, Severity

    def build(sub):
        cfg = get_smoke_config("internlm2-20b")
        ec = EngineConfig(mode="collocated", num_dp=2, max_batch=2,
                          max_seq=96, block_size=8, num_blocks=64,
                          workdir=str(tmp_path / sub),
                          sampling=SamplingParams(temperature=0.8,
                                                  top_p=0.9, seed=5))
        return cfg, InferenceEngine(cfg, ec)

    cfg, ref = build("ref")
    rng = np.random.default_rng(9)
    prompts = [list(rng.integers(0, cfg.vocab_size, 60)),
               list(rng.integers(0, cfg.vocab_size, 58))]
    want = _serve(ref, cfg, prompts, max_new=6)

    _, eng = build("fault")
    # both ranks get one long prompt; rank 1 dies mid-step during the
    # chunked prefill (step 2: 60 tokens span >= 2 chunks of 32)
    eng.injector.schedule(2, 1, severity=Severity.L6,
                          error_type=ErrorType.HBM_ECC, component="attn",
                          mid_step=True)
    got = _serve(eng, cfg, prompts, max_new=6)
    assert got == want
    surviving = [ex for ex in eng.dp_executors if ex.alive]
    assert surviving and all(
        ex.block_manager.num_allocated == 0 for ex in surviving)


def test_migrate_prefix_shared_request(tmp_path):
    """KV-block streaming of a request whose leading blocks are
    ref-shared with a co-resident: the gather reads the shared blocks in
    place, the target continues the exact token stream, and the source's
    refcounts survive the departure (the co-resident keeps decoding)."""
    cfg, src = _engine(tmp_path, sub="src")
    _, ref = _engine(tmp_path, sub="ref")
    _, tgt = _engine(tmp_path, sub="tgt")
    rng = np.random.default_rng(11)
    sysp = list(rng.integers(0, cfg.vocab_size, 24))     # 3 full blocks
    pa = sysp + list(rng.integers(0, cfg.vocab_size, 4))
    pb = sysp + list(rng.integers(0, cfg.vocab_size, 5))

    # reference: pb served start-to-finish, unmigrated, uncached engine
    rb_ref = ref.submit(list(pb), 10)
    ref.run(max_steps=200)

    ra = src.submit(list(pa), 24)
    src.step()                       # pa prefilled, blocks registered
    rb = src.submit(list(pb), 10)
    for _ in range(3):
        src.step()
    assert 0 < len(rb.output_tokens) < 10
    ex = src.dp_executors[0]
    shared = [bid for bid in ex.scheduler.block_tables[rb.req_id].blocks
              if ex.block_manager.ref_count(bid) > 1]
    assert len(shared) >= 3          # the 3 sysp blocks are ref-shared

    kv = ex.export_kv_blocks(rb)
    assert kv is not None
    # departure releases rb's share of the blocks (the engine export
    # path drives this same drain); the co-resident keeps its refs
    ex.scheduler.running.remove(rb)
    ex.scheduler._release(rb, None)
    for bid in shared:
        assert ex.block_manager.ref_count(bid) >= 1   # pa still owns them
    ex.scheduler.check_consistent()

    assert tgt.dp_executors[0].import_kv_blocks(rb, kv)
    tgt.all_requests.append(rb)
    tgt.run(max_steps=100)
    assert rb.state.value == "finished"
    assert list(rb.output_tokens) == list(rb_ref.output_tokens)
    assert rb.recomputed_tokens == 0
    # pa unharmed by the departure
    src.run(max_steps=200)
    assert ra.state.value == "finished"


def test_window_occupancy_stays_o_window(tmp_path):
    """ROADMAP follow-up (b): sliding-window configs free blocks decode
    (and chunked prefill) has slid past — peak pool occupancy is
    O(window + chunk), independent of prompt length."""
    cfg, eng = _engine(tmp_path, windowed=True, max_seq=160)
    rng = np.random.default_rng(0)
    peaks = {}
    for P in (88, 120):
        r = eng.submit(list(rng.integers(0, cfg.vocab_size, P)), 24)
        peak = 0
        while eng.unfinished:
            eng.step()
            peak = max(peak,
                       eng.dp_executors[0].block_manager.num_allocated)
        assert r.state.value == "finished"
        peaks[P] = peak
    # window (16 tok) + chunk (32 tok) at block_size 8, +straddle slack
    assert peaks[88] == peaks[120] <= (16 + 32) // 8 + 2, peaks
    assert eng.prefill_stats()["blocks_window_freed"] > 0
    assert eng.dp_executors[0].block_manager.num_allocated == 0


def test_window_release_token_parity(tmp_path):
    """Freeing out-of-window blocks must never touch a position the
    current step still attends (the window lower bound is inclusive):
    a releasing engine and one with release disabled must produce the
    identical token stream across many block-boundary crossings."""
    cfg, rel = _engine(tmp_path, windowed=True, max_seq=128, sub="rel")
    _, keep = _engine(tmp_path, windowed=True, max_seq=128, sub="keep")
    for ex in keep.dp_executors:
        ex.scheduler.window = None        # reference: no release
    rng = np.random.default_rng(2)
    prompts = [list(rng.integers(0, cfg.vocab_size, 24)),
               list(rng.integers(0, cfg.vocab_size, 11))]
    a = _serve(rel, cfg, prompts, max_new=40)
    b = _serve(keep, cfg, prompts, max_new=40)
    assert a == b
    assert rel.prefill_stats()["blocks_window_freed"] > 0


def test_windowed_stream_migration_skips_dead_blocks():
    """KV-block export of a windowed request ships no rows for window-
    released table entries, and the import installs trash sentinels for
    them instead of burning real blocks — the target then continues the
    exact token stream."""
    from repro.models.model import Model
    from repro.serving import cache_ops
    from repro.serving.executor import DPExecutor

    cfg = dataclasses.replace(get_smoke_config("internlm2-20b"),
                              sliding_window=16)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    class Ctx:
        def __init__(self, ex):
            self.params, self.runtime, self.ex = (params,
                                                  model.default_runtime(),
                                                  ex)

        def decode_fn(self, p, c, t, page, rt):
            page = {k: jnp.asarray(v) for k, v in page.items()}
            return model.decode_step_paged(p, c, jnp.asarray(t), page, rt)

        def chunk_fn(self):
            return self.decode_fn

        def prefill_fn(self, b):
            def fn(p, t, l, rt):
                return model.prefill_paged(
                    p, {"tokens": jnp.asarray(t),
                        "lengths": jnp.asarray(l)}, rt)
            return fn

        def install_fn(self, b):
            def fn(c, raw, bids, slot):
                return cache_ops.install_prefill(
                    c, raw, self.ex.paged_axes, jnp.asarray(bids),
                    jnp.int32(slot))
            return fn

    def mk(pid):
        return DPExecutor(physical_id=pid, dp_rank=pid, model=model,
                          max_batch=2, max_seq=64, num_blocks=24,
                          block_size=4, sampling=SamplingParams())

    prompt = list(np.random.default_rng(3).integers(0, cfg.vocab_size, 20))

    def run_steps(ex, ctx, req, until_tokens):
        for step in range(64):
            if len(req.output_tokens) >= until_tokens:
                break
            ex.plan()
            ex.compute(ctx, step)
            ex.commit()

    # reference: decodes to the end unmigrated
    ex_ref = mk(0)
    ctx_ref = Ctx(ex_ref)
    r_ref = Request(list(prompt), 20)
    ex_ref.scheduler.add_request(r_ref)
    run_steps(ex_ref, ctx_ref, r_ref, 20)

    ex = mk(1)
    ctx = Ctx(ex)
    r = Request(list(prompt), 20)
    ex.scheduler.add_request(r)
    run_steps(ex, ctx, r, 12)     # decode well past the 16-token window
    kv = ex.export_kv_blocks(r)
    assert kv is not None and kv.live_mask is not None
    dead = kv.live_mask.count(False)
    assert dead > 0               # window release left trash sentinels

    tgt = mk(2)
    before = tgt.block_manager.num_allocatable
    assert tgt.import_kv_blocks(r, kv)
    spent = before - tgt.block_manager.num_allocatable
    assert spent < kv.num_blocks  # dead entries cost no real blocks
    tgt.scheduler.check_consistent()
    ctx_t = Ctx(tgt)
    run_steps(tgt, ctx_t, r, 20)
    assert r.output_tokens == r_ref.output_tokens


def test_requeue_accounts_against_token_budget():
    """Satellite: a rollback-requeued request re-admits through the
    budgeted chunked path — its re-prefill is charged like any arrival
    (the old scheduler requeued outside admission accounting)."""
    bm = BlockManager(num_blocks=32, block_size=4)
    sched = LocalScheduler(max_batch=2, max_seq=64, block_manager=bm,
                           token_budget=8, chunk_tokens=8)
    log = BlockLog()
    r = Request(list(range(20)), 4)
    sched.add_request(r)
    log.begin_step()
    plan = sched.plan_step(log)
    (piece,) = plan.chunks
    assert piece.length == 8                  # budget-capped first chunk
    r.prefill_pos = piece.start + piece.length
    log.begin_step()                          # commit

    # next step's chunk is planned, then the step faults and rolls back
    plan = sched.plan_step(log)
    (piece,) = plan.chunks
    assert (piece.start, piece.length) == (8, 8)
    log.undo_all(bm, sched.block_tables)
    assert sched.rollback_aborted() == []     # admitted earlier: survives
    assert r.prefill_pos == 8                 # compute never ran

    # a full export/requeue resets the request; its re-admission is
    # budget-capped again rather than planned as one whole prefill
    for req in sched.drain():
        sched.requeue_front(req)
    assert bm.num_allocated == 0
    log.begin_step()
    plan = sched.plan_step(log)
    (piece,) = plan.chunks
    assert piece.req is r and piece.length == 8
    total = sum(p.length for p in plan.chunks) + len(plan.decode)
    assert total <= 8                         # token budget holds


def test_window_release_unblocks_exhausted_pool_during_prefill():
    """A windowed long prompt whose lazy chunked prefill exhausts the
    pool must keep making progress by releasing its own out-of-window
    blocks before growing the table (no silent livelock)."""
    bm = BlockManager(num_blocks=6, block_size=4)
    sched = LocalScheduler(max_batch=1, max_seq=64, block_manager=bm,
                           chunk_tokens=8, window=16)
    r = Request(list(range(60)), 4)
    sched.add_request(r)
    log = BlockLog()
    log.begin_step()
    for _ in range(30):
        plan = sched.plan_step(log)
        for piece in plan.chunks:
            r.prefill_pos = piece.start + piece.length
        log.begin_step()
        if r.prefill_pos >= 60:
            break
    assert r.prefill_pos >= 60, "chunked prefill livelocked"
    assert sched.stats["blocks_window_freed"] > 0


def test_window_release_prevents_decode_pool_exhaustion():
    """Decode growth at a full pool must free the request's dead
    out-of-window block first instead of raising 'out of KV blocks'."""
    bm = BlockManager(num_blocks=3, block_size=4)
    sched = LocalScheduler(max_batch=1, max_seq=64, block_manager=bm,
                           chunk_tokens=8, window=8)
    r = Request(list(range(6)), 40)
    sched.add_request(r)
    log = BlockLog()
    log.begin_step()
    plan = sched.plan_step(log)
    (piece,) = plan.chunks
    r.prefill_pos = piece.start + piece.length
    log.begin_step()
    for _ in range(34):
        r.output_tokens.append(1)
        sched.plan_step(log)          # previously raised at num_tokens=12
        log.begin_step()
        assert bm.num_allocated <= 3
    sched.check_consistent()


def test_prefix_affinity_routing_unit():
    """Router admission: a repeated prompt prefix sticks to the instance
    that served it last — until that instance falls too far behind the
    least-loaded one."""
    from collections import OrderedDict

    from repro.fleet.router import FleetRouter

    class Inst:
        def __init__(self, iid, load):
            self.iid, self.load = iid, load

    r = FleetRouter.__new__(FleetRouter)
    r.prefix_affinity = True
    r._affinity = OrderedDict()
    a, b = Inst(0, 0), Inst(1, 0)
    p1 = list(range(40))
    first = r._route([a, b], p1)
    assert r._route([a, b], p1) is first      # sticky on equal load
    # a long prompt sharing only a short system prefix (< the longest
    # key) still matches through the prefix-length ladder
    shared_short = p1[:12] + list(range(900, 928))
    assert r._route([a, b], shared_short) is first
    # overload breaks affinity: the sticky instance is now far busier
    first.load = 10
    assert r._route([a, b], p1) is not first
    # affinity disabled -> pure least-loaded
    r.prefix_affinity = False
    a.load, b.load = 5, 1
    assert r._route([a, b], p1) is b
    # LRU bound: one-off prefixes age out individually, a periodically
    # re-seen hot key survives the churn
    r.prefix_affinity = True
    a.load = b.load = 0
    hot = tuple(range(40))
    r._route([a, b], list(hot))
    for i in range(FleetRouter._AFFINITY_MAP_MAX + 64):
        r._route([a, b], list(range(100 + i, 140 + i)))
        if i % 512 == 0:
            r._route([a, b], list(hot))
    assert hot[: FleetRouter.AFFINITY_LENS[0]] in r._affinity
    assert len(r._affinity) <= FleetRouter._AFFINITY_MAP_MAX


def test_rollback_aborted_preserves_fifo_order():
    """Two admissions in one aborted step must requeue in arrival
    order: requeue_front prepends, so rollback walks the aborted list
    in reverse (a forward walk would leave [B, A] and invert FIFO)."""
    bm = BlockManager(num_blocks=32, block_size=4)
    sched = LocalScheduler(max_batch=4, max_seq=64, block_manager=bm,
                           token_budget=64, chunk_tokens=32)
    log = BlockLog()
    ra = Request(list(range(10)), 4)
    rb = Request(list(range(10, 22)), 4)
    sched.add_request(ra)
    sched.add_request(rb)
    log.begin_step()
    plan = sched.plan_step(log)
    assert [p.req for p in plan.chunks] == [ra, rb]  # both admitted
    log.undo_all(bm, sched.block_tables)
    aborted = sched.rollback_aborted()
    assert {r.req_id for r in aborted} == {ra.req_id, rb.req_id}
    assert list(sched.waiting) == [ra, rb]           # FIFO preserved
    sched.check_consistent()
