"""Randomized fault-sequence fuzzing of the whole serving+recovery stack.

For arbitrary (seeded) schedules of device failures — any component, any
step, mid-step or boundary — the system must either finish every request
or degrade gracefully, and the host-side invariants must hold afterwards:

  * every non-failed request finished with exactly max_new_tokens,
  * block accounting consistent (all blocks freed once traffic drains),
  * expert-map runtime arrays consistent with slot liveness,
  * no executor serves while its device is dead.

This is the paper's reliability claim under test, beyond the
single-failure scenarios of Figure 5.
"""
import dataclasses

import numpy as np
import pytest

# randomized end-to-end engine runs: tier-2 only
pytestmark = pytest.mark.slow

from repro.configs import get_smoke_config
from repro.core.fault_codes import ErrorType, Severity
from repro.core.weights import RecoveryPolicy
from repro.serving.engine import EngineConfig, InferenceEngine

SEEDS = [0, 1, 2]


def build_engine(tmp_path, seed):
    cfg = get_smoke_config("qwen2-moe-a2.7b")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, num_experts=4,
                                     num_redundant_experts=4, top_k=2))
    ec = EngineConfig(mode="disaggregated", num_dp=3, num_moe=2,
                      max_batch=2, max_seq=64, block_size=8, num_blocks=96,
                      workdir=str(tmp_path),
                      policy=RecoveryPolicy(min_ep_for_missing=2))
    return cfg, InferenceEngine(cfg, ec)


@pytest.mark.parametrize("seed", SEEDS)
def test_random_fault_schedule(tmp_path, seed):
    rng = np.random.default_rng(seed)
    cfg, eng = build_engine(tmp_path / f"s{seed}", seed)
    reqs = [eng.submit(list(rng.integers(0, cfg.vocab_size,
                                         int(rng.integers(4, 12)))),
                       max_new_tokens=int(rng.integers(4, 10)))
            for _ in range(6)]

    # random faults: 1-2 failures on random devices; never kill the last
    # attention rank (out of scope for ReviveMoE: whole-service loss)
    n_faults = int(rng.integers(1, 3))
    victims = rng.choice([1, 2, 3, 4], size=n_faults, replace=False)
    for v in victims:
        eng.injector.schedule(
            int(rng.integers(2, 8)), int(v),
            severity=Severity(int(rng.integers(3, 7))),
            error_type=ErrorType.HBM_ECC,
            component="moe" if v >= 3 else "attn",
            mid_step=bool(rng.integers(0, 2)))

    eng.run(max_steps=300)

    # every request completed despite the failures
    for r in reqs:
        assert r.state.value == "finished", (seed, r.req_id, r.state)
        assert len(r.output_tokens) == r.max_new_tokens

    # block accounting drained on every surviving executor
    for ex in eng.dp_executors:
        if ex.alive and ex.cache is not None:
            assert ex.block_manager.num_allocated == 0, (
                seed, ex.physical_id, ex.block_manager.num_allocated)
            assert ex.scheduler.num_requests == 0

    # expert runtime arrays consistent with the map's slot liveness
    if eng.expert_map is not None:
        emap = eng.expert_map
        rt = eng.runtime
        l2p = np.asarray(rt.logical_to_physical)
        count = np.asarray(rt.replica_count)
        for e in range(cfg.moe.num_experts):
            for i in range(count[e]):
                slot = l2p[e, i]
                assert emap.slot_alive[slot], (seed, e, slot)
                assert emap.slot_logical[slot] == e

    # dead devices never appear in the serving path
    for ex in eng.dp_executors:
        if not ex.device_alive:
            assert not ex.process_alive or ex.cache is None


@pytest.mark.parametrize("seed", [7])
def test_two_sequential_moe_failures(tmp_path, seed):
    """Second failure after a role switch: the switched rank's experts are
    covered again; losing the OTHER MoE rank must still recover."""
    rng = np.random.default_rng(seed)
    cfg = get_smoke_config("qwen2-moe-a2.7b")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, num_experts=4,
                                     num_redundant_experts=0, top_k=2))
    ec = EngineConfig(mode="disaggregated", num_dp=4, num_moe=2,
                      max_batch=2, max_seq=64, block_size=8, num_blocks=96,
                      workdir=str(tmp_path),
                      policy=RecoveryPolicy(min_ep_for_missing=2))
    eng = InferenceEngine(cfg, ec)
    reqs = [eng.submit(list(rng.integers(0, cfg.vocab_size, 8)), 20)
            for _ in range(6)]
    eng.injector.schedule(3, 4, severity=Severity.L6, component="moe")
    eng.injector.schedule(8, 5, severity=Severity.L6, component="moe")
    eng.run(max_steps=300)
    assert len(eng.reports) == 2
    assert all(r.state.value == "finished" for r in reqs)
    checks, alive = eng.expert_integrity()
    assert all(alive)  # both failures ended with full weight integrity
