"""Property + unit tests for the §3.4 expert map and recovery planner."""
from __future__ import annotations

import numpy as np
import pytest

pytest.importorskip("hypothesis")

import hypothesis.strategies as st
from hypothesis import given, settings

from repro.configs.base import MoEConfig
from repro.core.expert_map import ExpertMap
from repro.core.weights import (DenseFFNGroups, MoERecoveryKind,
                                RecoveryPolicy, plan_moe_recovery)


def mk_map(E=16, R=8, ep=4, k=2):
    moe = MoEConfig(num_experts=E, top_k=k, expert_d_ff=64,
                    num_redundant_experts=R)
    return ExpertMap(moe, ep)


@settings(max_examples=100, deadline=None)
@given(E=st.sampled_from([8, 16, 32]),
       ep=st.sampled_from([2, 4, 8]),
       fail_rank=st.integers(0, 7))
def test_runtime_consistency(E, ep, fail_rank):
    """The runtime arrays always point at alive physical slots of the
    right logical expert, and mask == (no replica or masked)."""
    R = E // 2
    if (E + R) % ep:
        R = E            # make physical count divisible
    emap = mk_map(E=E, R=R, ep=ep)
    emap.fail_rank(fail_rank % ep)
    rt = emap.runtime()
    l2p = np.asarray(rt.logical_to_physical)
    count = np.asarray(rt.replica_count)
    mask = np.asarray(rt.expert_mask)
    for e in range(E):
        for i in range(count[e]):
            slot = l2p[e, i]
            assert emap.slot_alive[slot]
            assert emap.slot_logical[slot] == e
        assert mask[e] == (count[e] > 0 and e not in emap.masked)


def test_fail_rank_then_redundant_coverage():
    # every expert replicated once: any single rank failure is covered
    emap = mk_map(E=8, R=8, ep=4)
    emap.fail_rank(1)
    assert emap.fully_lost() == []
    assert emap.coverage() == 1.0
    plan = plan_moe_recovery(emap, RecoveryPolicy(), donor_rank=None)
    assert plan.kind is MoERecoveryKind.REDUNDANT_EXPERTS


def test_unreplicated_loss_routes_to_role_switch_then_missing():
    emap = mk_map(E=16, R=0, ep=4)
    lost = emap.fail_rank(2)
    assert lost == [8, 9, 10, 11]
    assert set(emap.fully_lost()) == {8, 9, 10, 11}
    plan = plan_moe_recovery(emap, RecoveryPolicy(), donor_rank=1)
    assert plan.kind is MoERecoveryKind.ROLE_SWITCH
    assert plan.donor_rank == 1
    # no donor available -> missing experts (with EP warning below 32)
    plan2 = plan_moe_recovery(emap, RecoveryPolicy(), donor_rank=None)
    assert plan2.kind is MoERecoveryKind.MISSING_EXPERTS
    assert plan2.accuracy_warning  # ep=4 < 32 (§4.2 threshold)


def test_role_switch_install_restores_coverage():
    emap = mk_map(E=16, R=0, ep=4)
    emap.fail_rank(2)
    assert emap.coverage() < 1.0
    restored = emap.install_rank(2)
    assert sorted(restored) == [8, 9, 10, 11]
    assert emap.coverage() == 1.0
    rt = emap.runtime()
    assert bool(np.all(np.asarray(rt.expert_mask)))


def test_mask_experts_reflects_in_runtime():
    emap = mk_map()
    emap.fail_rank(0)
    lost = emap.fully_lost()
    emap.mask_experts(lost)
    rt = emap.runtime()
    mask = np.asarray(rt.expert_mask)
    for e in lost:
        assert not mask[e]


def test_losing_last_replica_of_redundant_expert():
    """§4.3: redundancy is by usage, so the last copy can still die."""
    emap = mk_map(E=8, R=4, ep=4)  # slots: 0-7 base, 8-11 replicas of 0-3
    # rank 0 holds slots 0-2 (logicals 0,1,2); replicas of 0,1,2 exist
    emap.fail_rank(0)
    assert emap.fully_lost() == []
    # rank 2 holds slots 6,7,8 -> logicals 6,7 (unreplicated) AND the
    # replica of 0 — whose base copy already died with rank 0: even a
    # redundant expert is lost once its last copy goes (§4.3)
    emap.fail_rank(2)
    assert set(emap.fully_lost()) == {0, 6, 7}


def test_dense_ffn_group_rebalance():
    g = DenseFFNGroups(4)
    assert g.routing_weights() == [0.25] * 4
    g.fail_shard(1)
    w = g.routing_weights()
    assert w[1] == 0.0 and abs(sum(w) - 1.0) < 1e-9
    assert all(abs(x - 1 / 3) < 1e-9 for i, x in enumerate(w) if i != 1)
